"""Oracle suite for step-demand semantics: the packed profile
(``step_demand_profile``), its incremental twin
(``IncrementalDemandProfile``), the window probe (``demand_exceeds``) and
the batched admission program are all checked against a brute-force oracle
that evaluates Eq. (1) naively — per plan, per probe time, no profiles, no
cumulative sums.  Boundary-epsilon probes (at, just before, and just after
every event instant) are always included.

Each property runs both ways: as a hypothesis ``@given`` test (random seeds,
shrinking — skipped cleanly by the conftest shim when hypothesis is absent)
and as a seeded example loop that keeps coverage in minimal environments.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.allocation import (
    IncrementalDemandProfile,
    StepAllocation,
    demand_exceeds,
    pack_step_allocations,
    step_demand_profile,
)

SEEDS = [0, 1, 2, 7, 19, 101]


def _random_plan(rng) -> tuple[StepAllocation, float, float]:
    """(alloc, start, release) with admission-style release just past r_e."""
    k = int(rng.integers(1, 6))
    bounds = np.sort(rng.uniform(0.5, 50.0, k))
    values = np.maximum.accumulate(rng.uniform(10.0, 500.0, k))
    start = float(rng.uniform(0.0, 100.0))
    release = float(np.nextafter(start + bounds[-1], np.inf))
    return StepAllocation(bounds, values), start, release


def _oracle_value(alloc: StepAllocation, start: float, t: float) -> float:
    """Naive Eq. (1): the step to segment s+1 fires at the first representable
    instant after the switch time ``start + b_s`` (right-open steps)."""
    idx = 0
    for b in alloc.boundaries[:-1]:
        if t >= np.nextafter(start + b, np.inf):
            idx += 1
    return float(alloc.values[idx])


def _oracle_total(plans, t: float) -> float:
    """Naive total demand: sum the live plans' values, one at a time."""
    tot = 0.0
    for alloc, start, release in plans:
        if start <= t < release:
            tot += _oracle_value(alloc, start, t)
    return tot


def _event_times(plans) -> np.ndarray:
    ev = []
    for alloc, start, release in plans:
        ev.append(start)
        ev.extend(np.nextafter(start + alloc.boundaries, np.inf))
        ev.append(release)
    return np.asarray(ev)


def _probe_times(plans, rng) -> np.ndarray:
    """Random times plus every boundary-epsilon case: each event instant,
    one ulp before, and one ulp after."""
    ev = _event_times(plans)
    return np.concatenate(
        [
            rng.uniform(-5.0, 160.0, 64),
            ev,
            np.nextafter(ev, -np.inf),
            np.nextafter(ev, np.inf),
        ]
    )


def _profile_arrays(plans):
    bnd, val = pack_step_allocations([a for a, _, _ in plans])
    starts = np.asarray([s for _, s, _ in plans])
    releases = np.asarray([r for _, _, r in plans])
    return step_demand_profile(bnd, val, starts, releases)


def _check_profile_matches_oracle(seed: int) -> None:
    rng = np.random.default_rng(seed)
    plans = [_random_plan(rng) for _ in range(int(rng.integers(1, 9)))]
    times, cum = _profile_arrays(plans)
    for t in _probe_times(plans, rng):
        got = cum[np.searchsorted(times, t, side="right")]
        want = _oracle_total(plans, t)
        assert np.isclose(got, want, rtol=1e-9, atol=1e-6), (t, got, want)


def _check_incremental_matches_oracle(seed: int) -> None:
    """add/remove/expire churn must leave the incremental profile reading
    exactly like the naive oracle over the surviving plans."""
    rng = np.random.default_rng(seed)
    prof = IncrementalDemandProfile()
    livemap = {}
    for i in range(int(rng.integers(4, 12))):
        alloc, start, release = _random_plan(rng)
        prof.add(i, alloc.boundaries, alloc.values, start, release)
        livemap[i] = (alloc, start, release)
    for i in list(livemap):
        if rng.random() < 0.4:
            prof.remove(i)
            del livemap[i]
    plans = list(livemap.values())
    times, cum = prof.arrays()
    for t in _probe_times(plans, rng) if plans else np.linspace(0, 100, 16):
        got = cum[np.searchsorted(times, t, side="right")]
        want = _oracle_total(plans, t)
        assert np.isclose(got, want, rtol=1e-9, atol=1e-6), (t, got, want)
    # expire at a random instant only drops fully-released plans: readings at
    # t >= now are unchanged
    if plans:
        now = float(rng.uniform(0.0, 200.0))
        prof.expire(now)
        times2, cum2 = prof.arrays()
        for t in np.concatenate([[now], rng.uniform(now, now + 100.0, 16)]):
            got = cum2[np.searchsorted(times2, t, side="right")]
            assert np.isclose(got, _oracle_total(plans, t), rtol=1e-9, atol=1e-6)


def _check_demand_exceeds_matches_oracle(seed: int) -> None:
    """The probe's boolean must match the naive window max: the combined
    step function over [start, end] attains its max at some event/boundary
    instant, so the oracle evaluates all of them plus epsilon neighbours."""
    rng = np.random.default_rng(seed)
    plans = [_random_plan(rng) for _ in range(int(rng.integers(1, 7)))]
    times, cum = _profile_arrays(plans)
    cand, start, _ = _random_plan(rng)
    end = start + float(cand.boundaries[-1])
    pts = np.concatenate(
        [
            [start, end],
            _probe_times(plans, rng),
            np.nextafter(start + cand.boundaries, np.inf),
        ]
    )
    pts = pts[(pts >= start) & (pts <= end)]
    peak = max(_oracle_total(plans, t) + _oracle_value(cand, start, t) for t in pts)
    for budget, want in [(peak * (1 + 1e-6), False), (peak * (1 - 1e-6), True)]:
        got = demand_exceeds(times, cum, cand, start, end, budget, inclusive_end=True)
        assert got == want, (budget, peak, got)


@pytest.mark.parametrize("seed", SEEDS)
def test_profile_matches_oracle(seed):
    _check_profile_matches_oracle(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_incremental_profile_matches_oracle(seed):
    _check_incremental_matches_oracle(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_demand_exceeds_matches_oracle(seed):
    _check_demand_exceeds_matches_oracle(seed)


def test_profile_boundary_epsilon_exact():
    """Pinned boundary semantics: AT a switch instant the profile reads the
    stepped value; one ulp before, the pre-step value; at the release, zero."""
    alloc = StepAllocation(np.asarray([10.0, 20.0]), np.asarray([100.0, 500.0]))
    start, release = 5.0, float(np.nextafter(25.0, np.inf))
    plans = [(alloc, start, release)]
    times, cum = _profile_arrays(plans)

    def read(t):
        return cum[np.searchsorted(times, t, side="right")]

    sw = np.nextafter(15.0, np.inf)  # start + first boundary, right-open
    assert read(np.nextafter(sw, -np.inf)) == 100.0
    assert read(sw) == 500.0
    assert read(25.0) == 500.0  # holds through r_e inclusive
    assert read(release) == 0.0


def test_incremental_remove_is_exact_inverse():
    """After add + remove the arrays are identical to never having added."""
    rng = np.random.default_rng(3)
    prof = IncrementalDemandProfile()
    a1, s1, r1 = _random_plan(rng)
    prof.add("keep", a1.boundaries, a1.values, s1, r1)
    t_before, c_before = (x.copy() for x in prof.arrays())
    a2, s2, r2 = _random_plan(rng)
    prof.add("gone", a2.boundaries, a2.values, s2, r2)
    prof.remove("gone")
    t_after, c_after = prof.arrays()
    np.testing.assert_array_equal(t_before, t_after)
    np.testing.assert_array_equal(c_before, c_after)
    assert "keep" in prof and "gone" not in prof


# -- hypothesis variants (skip cleanly under the conftest shim) -------------


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 2**31 - 1))
def test_property_profile_matches_oracle(seed):
    _check_profile_matches_oracle(seed)


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 2**31 - 1))
def test_property_incremental_matches_oracle(seed):
    _check_incremental_matches_oracle(seed)


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 2**31 - 1))
def test_property_demand_exceeds_matches_oracle(seed):
    _check_demand_exceeds_matches_oracle(seed)
