"""MUST-FLAG RA006: Python control flow on tracer-valued tests.

An `if` on a jnp predicate inside a jit body raises
ConcretizationTypeError (or, pre-jit, silently specializes the program
on one branch); a `while` on a device comparison is the same bug.
"""

import jax
import jax.numpy as jnp


@jax.jit
def clip_over_budget(x, budget):
    if jnp.any(x > budget):
        return jnp.minimum(x, budget)
    return x


@jax.jit
def drain(x):
    while jnp.sum(x) > 0:
        x = x - 1
    return x
