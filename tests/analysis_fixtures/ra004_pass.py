"""MUST-PASS RA004: the sanctioned ladder-selection spellings.

The batch_engine pattern: dtype derived from the x64 flag via the
conditional expression, and float32 as a *signature default* (callers
override it through the ladder) — both exempt.
"""

import jax.numpy as jnp

from repro.sim.device_timeline import _x64_ctx


def ladder(y, *, x64=False):
    dt = jnp.float64 if x64 else jnp.float32
    acc = jnp.zeros((), dt)
    with _x64_ctx():
        return acc + y.sum().astype(dt)


def engine(y, dtype=jnp.float32):
    return y.astype(dtype).sum()
