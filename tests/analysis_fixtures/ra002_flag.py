"""MUST-FLAG RA002: PR 6's heap-corruption class, verbatim shape.

Donated-buffer executables deserialized from the persistent compile
cache crash jax 0.4.37's XLA:CPU (use-after-free on the donated input).
Unconditional donation is therefore a latent crash on every CPU CI run
with REPRO_COMPILE_CACHE set.
"""

import jax


def make_step(train_step):
    return jax.jit(train_step, donate_argnums=(0,))
