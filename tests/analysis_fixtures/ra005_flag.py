"""MUST-FLAG RA005: raw enable_x64 outside device_timeline.py.

This was the live finding in serve/admission.py and sim/cluster.py that
this rule was written from: each module re-imported enable_x64 and
re-entered the config context even when x64 was already the global
default, forking the trace-context story across the jit caches.
"""

from jax.experimental import enable_x64


def dispatch(program, *args):
    with enable_x64():
        return program(*args)
