"""MUST-FLAG RA004: dtype-literal drift in an x64-parity module.

The module imports the shared ladder context (making it x64-parity
code); `ladder` threads the `x64` flag but hard-codes float32 in its
body — exactly the f32-ulp drift `ladder_x64` was added to close.
"""

import jax.numpy as jnp

from repro.sim.device_timeline import _x64_ctx


def ladder(y, *, x64=False):
    acc = jnp.zeros((), jnp.float32)
    with _x64_ctx():
        return acc + y.sum()
