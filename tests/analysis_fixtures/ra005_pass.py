"""MUST-PASS RA005: the shared ladder context from device_timeline.

`_x64_ctx()` no-ops when jax_enable_x64 is already on, so warm dispatch
keeps one trace context (and therefore one jit-cache entry) regardless
of the global flag.
"""

from repro.sim.device_timeline import _x64_ctx


def dispatch(program, *args):
    with _x64_ctx():
        return program(*args)
