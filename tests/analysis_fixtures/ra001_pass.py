"""MUST-PASS RA001: the sanctioned replacements, plus host-numpy use.

`lax.cummax` is the tracing-safe prefix max; `np.maximum.accumulate` on
host arrays (benchmark post-processing) is fine — RA001 is jnp-only.
"""

import numpy as np
from jax import lax


def forward_fill_peaks(v):
    return lax.cummax(v)


def host_fill(v):
    return np.maximum.accumulate(np.asarray(v))
