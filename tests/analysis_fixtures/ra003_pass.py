"""MUST-PASS RA003: the same operations where they are legitimate.

Host syncs in plain host wrappers (the repo's `first_fit_window` /
`sweep_schedule` pattern: dispatch the program, then np.asarray the
result) are fine — RA003 only applies inside traced scopes.
"""

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def device_program(x):
    return jnp.cumsum(x) * x.max()


def host_wrapper(x):
    out = np.asarray(device_program(jnp.asarray(x)))
    return float(out[-1]), out.tolist()
