"""MUST-FLAG RA003: host syncs inside traced bodies.

Covers all three detector branches: .item(), np.asarray(tracer), and
builtin float()/int() on a traced value — in a decorated jit function,
a scan body passed by name, and a lambda passed to fori_loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@jax.jit
def jit_body(x):
    peak = x.max().item()
    host = np.asarray(x)
    return x * peak + host.sum()


def scan_step(carry, x):
    return carry + float(x), None


def run(xs):
    return lax.scan(scan_step, 0.0, xs)


def loop(xs):
    return lax.fori_loop(0, 8, lambda i, c: c + int(xs[i]), 0)
