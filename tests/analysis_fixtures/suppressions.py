"""Suppression-syntax fixture: inline ignores and their edge cases."""

import jax.numpy as jnp


def fill_suppressed(v):
    return jnp.maximum.accumulate(v)  # ra: ignore[RA001]


def fill_blanket(v):
    return jnp.maximum.accumulate(v)  # ra: ignore


def fill_wrong_rule(v):
    return jnp.maximum.accumulate(v)  # ra: ignore[RA003]
