"""MUST-PASS RA006: structured control flow, and static Python branches.

`jnp.where`/`lax.cond` express the branch in-program; an `if` on a
*static* Python value (config, shape) inside a jit body is legitimate
trace-time specialization and must not flag.
"""

import jax
import jax.numpy as jnp
from jax import lax


@jax.jit
def clip_over_budget(x, budget):
    return jnp.where(x > budget, jnp.minimum(x, budget), x)


def make_program(chunks: int):
    @jax.jit
    def run(x):
        if chunks > 1:
            x = x.reshape(chunks, -1).sum(axis=0)
        return lax.cond(x.size > 0, lambda v: v.sum(), lambda v: jnp.zeros(()), x)

    return run
