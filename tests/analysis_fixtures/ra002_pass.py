"""MUST-PASS RA002: the platform-guarded donation from train/trainer.py.

Donation is an off-CPU optimization only; the guard consults
jax.default_backend() in the same scope as the donate kwarg.
"""

import jax


def make_step(train_step):
    donate = () if jax.default_backend() == "cpu" else (0,)
    return jax.jit(train_step, donate_argnums=donate)
