"""MUST-FLAG RA001: the seed's segmentation bug, verbatim shape.

`jnp.maximum.accumulate` silently resolves to the *host numpy* ufunc
method (jax.numpy ufuncs don't implement .accumulate), so it concretizes
tracers and broke the k-segments forward fill until PR 1 replaced it
with `lax.cummax`.
"""

import jax.numpy as jnp


def forward_fill_peaks(v):
    return jnp.maximum.accumulate(v)


def pairwise_table(a, b):
    return jnp.add.outer(a, b)
