"""Fault tolerance: restart-from-checkpoint integration, stragglers, elastic."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig
from repro.distributed.elastic import plan_transition
from repro.distributed.fault_tolerance import SimulatedFailure, StragglerDetector, run_with_recovery
from repro.train import TrainConfig, Trainer, TrainerConfig


def test_recovery_resumes_from_checkpoint(tmp_path):
    cfg = get_config("llama3.2-3b").reduced()
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    tc = TrainerConfig(
        steps=16, checkpoint_every=5, checkpoint_dir=str(tmp_path),
        monitor_interval_s=0.05, monitor_task_steps=8, log_every=4,
    )
    fails = [12]

    def make_trainer():
        fa = fails.pop(0) if fails else None
        return Trainer(cfg, data_cfg, TrainConfig(), tc, fail_at_step=fa)

    state, restarts = run_with_recovery(make_trainer)
    assert restarts == 1
    assert int(np.asarray(state["step"])) == 16


def test_recovery_gives_up_after_max_restarts(tmp_path):
    cfg = get_config("llama3.2-3b").reduced()
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    tc = TrainerConfig(steps=8, checkpoint_every=100, checkpoint_dir=str(tmp_path), monitor_task_steps=8)

    def always_fail():
        return Trainer(cfg, data_cfg, TrainConfig(), tc, fail_at_step=2)

    with pytest.raises(SimulatedFailure):
        run_with_recovery(always_fail, max_restarts=2)


def test_straggler_detector():
    det = StragglerDetector(factor=1.5, min_observations=5)
    rng = np.random.default_rng(0)
    for _ in range(20):
        w = rng.uniform(10, 20)
        det.observe("step", w, 0.1 * w * (1 + rng.normal(0, 0.01)))
    assert not det.events
    assert det.observe("step", 15.0, 10.0)  # 10s vs ~1.5s predicted
    assert len(det.events) == 1
    ev = det.events[0]
    assert ev.runtime_s > 1.5 * ev.predicted_s


def test_elastic_plan_preserves_global_batch():
    p = plan_transition(global_batch=256, old_data=16, new_data=12, microbatch_per_device=1)
    assert p.global_batch == 256
    assert p.new_data * p.accum_steps * p.per_device_batch == 256
    p2 = plan_transition(global_batch=256, old_data=16, new_data=16, microbatch_per_device=2)
    assert p2.new_data * p2.accum_steps * p2.per_device_batch == 256
