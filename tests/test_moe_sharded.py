"""shard_map MoE equals the reference dispatch on a real multi-device mesh
(subprocess: 16 forced host devices)."""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.compat import use_mesh
from repro.configs import get_config
from repro.models.layers import init_moe, moe

mesh = Mesh(np.asarray(jax.devices()).reshape(4, 4), ("data", "model"))
out = {}
for name, shard in [("qwen3-moe-235b-a22b", "ep"), ("grok-1-314b", "tp")]:
    r = get_config(name).reduced()
    r = dataclasses.replace(r, num_experts=8, experts_per_token=2, moe_d_ff=64,
                            capacity_factor=16.0, moe_sharding=shard)
    p = init_moe(jax.random.PRNGKey(0), r)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, r.d_model), jnp.float32).astype(jnp.bfloat16)
    ref_out, _ = moe(p, x, r)  # no mesh -> reference path
    with use_mesh(mesh):
        f = jax.jit(lambda p, x: moe(p, x, r),
                    in_shardings=(None, NamedSharding(mesh, P(("data",), None, None))))
        got_out, got_aux = f(p, x)
    err = float(jnp.max(jnp.abs(ref_out.astype(jnp.float32) - got_out.astype(jnp.float32))))
    out[shard] = {"err": err, "aux": float(got_aux)}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def child_output():
    res = subprocess.run(
        [sys.executable, "-c", _CHILD, SRC], capture_output=True, text=True, timeout=900
    )
    assert res.returncode == 0, res.stderr[-2000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_ep_sharded_matches_reference(child_output):
    assert child_output["ep"]["err"] < 0.05


def test_tp_sharded_matches_reference(child_output):
    assert child_output["tp"]["err"] < 0.05


def test_aux_loss_sane(child_output):
    for k in ("ep", "tp"):
        assert 0.0 < child_output[k]["aux"] < 10.0
