"""The in-program wait path under congestion: corpora whose queued tasks far
exceed what the cluster can hold at once, so placement is dominated by waits
on future completions.

The batched scheduler must resolve every one of those waits inside the
device scheduling-epoch program (``device_timeline.schedule_epoch`` — the
event clock and release heap live in the scan carry) with **exact** (node,
start, end) per-attempt parity against the sequential ``run_cluster``
oracle, and the placement counters must show zero host-resolved waits.

Seeded corpora plus a hypothesis variant over random densities (skipped
cleanly by the conftest shim when hypothesis is absent).
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ksegments import KSegmentsConfig
from repro.sim.cluster import run_cluster, run_cluster_batched
from repro.sim.traces import generate_workflow

POLICIES = ("default", "witt-lr", "ppm-improved", "ksegments-selective")


def _assert_congested_parity(wfs, policies, min_waits: int, **kw):
    """Exact per-attempt parity + the wait-path invariants.

    Pinned to ``placement="windows"``: these corpora stress the epoch
    program's carry hand-off between windows dispatches, which the
    whole-run sweep engine (tests/test_cluster_sweep.py) never takes.
    """
    cfg = KSegmentsConfig(error_mode="progressive")
    stats: dict = {}
    batched = run_cluster_batched(wfs, policies, placement_stats=stats, placement="windows", **kw)
    # the point of the corpus: placement must actually have waited, and every
    # wait must have been resolved inside the device program
    assert stats["waits_host"] == 0
    assert stats["waits_program"] >= min_waits, stats
    seq_kw = {k: v for k, v in kw.items() if k != "placement_window"}
    for policy in policies:
        seq = run_cluster(wfs, policy, ksegments_config=cfg, **seq_kw)
        bat = batched[policy]
        assert seq.tasks_run == bat.tasks_run > 0
        assert seq.retries == bat.retries
        assert seq.makespan_s == bat.makespan_s
        for rs, rb in zip(seq.records, bat.records):
            assert (rs.workflow, rs.task, rs.exec_index) == (rb.workflow, rb.task, rb.exec_index)
            assert rs.attempts == rb.attempts
            assert rs.placements == rb.placements  # exact (node, start, end)
            np.testing.assert_allclose(rs.wastage_gib_s, rb.wastage_gib_s, rtol=1e-3, atol=1e-6)
    return stats


@pytest.mark.parametrize(
    "seed,name,scale,n_nodes,node_gib,mtpt,min_exec",
    [
        # single node, 24 GiB: every co-resident task contends
        (3, "eager", 0.25, 1, 24, 25, 6),
        (7, "eager", 0.25, 2, 24, 25, 6),
        (13, "sarek", 0.12, 2, 32, 8, 8),
    ],
)
def test_congested_corpus_exact_parity(seed, name, scale, n_nodes, node_gib, mtpt, min_exec):
    wfs = [generate_workflow(name, seed=seed, scale=scale)]
    # small nodes (vs the 128 GiB default): the corpora's biggest tasks
    # reserve a sizable fraction of a node, so the queue saturates the
    # cluster and rows genuinely wait on future completions
    _assert_congested_parity(
        wfs,
        POLICIES,
        min_waits=5,
        n_nodes=n_nodes,
        node_mib=node_gib * 1024.0,
        max_tasks_per_type=mtpt,
        min_executions=min_exec,
        train_frac=0.5,
    )


def test_congested_small_window_epochs():
    """Tiny placement windows force many epoch boundaries mid-wait: the
    carry hand-off (commits, heap, clock) between consecutive epoch
    dispatches must be seamless."""
    wfs = [generate_workflow("eager", seed=3, scale=0.25)]
    _assert_congested_parity(
        wfs,
        ("default", "ksegments-selective"),
        min_waits=5,
        n_nodes=1,
        node_mib=24 * 1024.0,
        max_tasks_per_type=25,
        min_executions=6,
        train_frac=0.5,
        placement_window=4,
    )


@settings(deadline=None, max_examples=5)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 3),
    st.integers(6, 14),
)
def test_property_congested_parity(seed, n_nodes, mtpt):
    """Random densities: whatever wait pattern the corpus produces, the
    batched engine must reproduce the oracle exactly and never fall back to
    a host-resolved wait."""
    wfs = [generate_workflow("eager", seed=seed, scale=0.06)]
    _assert_congested_parity(
        wfs,
        ("default", "ksegments-selective"),
        min_waits=0,
        n_nodes=n_nodes,
        node_mib=32 * 1024.0,
        max_tasks_per_type=mtpt,
        min_executions=6,
        train_frac=0.5,
    )


def test_schedule_epoch_waits_in_program():
    """Direct unit check of the epoch program's wait mechanics: a second row
    that cannot fit alongside the first must start exactly at the first's
    completion, consuming exactly one pending event."""
    from repro.sim.device_timeline import schedule_epoch

    bnd = np.asarray([[5.0], [5.0]])
    val = np.asarray([[700.0], [700.0]])  # 2 x 700 > 1000: row 1 must wait
    run = np.asarray([10.0, 10.0])
    placed, node, start, now_f, pops, waited, dead = schedule_epoch(
        0.0, bnd, val, run, [(np.empty(0), np.empty(0))], np.asarray([]), 1000.0 + 1e-6, 8
    )
    assert placed.tolist() == [True, True]
    assert node.tolist() == [0, 0]
    assert start.tolist() == [0.0, 10.0]  # row 1 waits for row 0's release
    assert now_f == 10.0
    assert pops == 1 and waited == 1 and not dead
