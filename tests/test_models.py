"""Per-architecture smoke tests (reduced configs) + prefill/decode consistency.

Every assigned arch instantiates a tiny same-family variant, runs one
forward (and a train-like loss/grad where cheap), checks shapes and NaNs,
and — for decoder archs — verifies that prefill+decode equals the full
forward at the next position (the KV-cache/recurrent-state contract).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import decode_step, forward, init_params

KEY = jax.random.PRNGKey(42)
B, T = 2, 37  # odd length stresses chunk padding


def _reduced(name):
    r = ARCHS[name].reduced()
    if r.num_experts:  # avoid capacity-drop nondeterminism in consistency checks
        r = dataclasses.replace(r, capacity_factor=8.0)
    return r


def _inputs(r, t):
    kwargs = {}
    tokens = jax.random.randint(KEY, (B, t), 0, r.vocab_size)
    if r.frontend == "audio_frames":
        kwargs["features"] = jax.random.normal(KEY, (B, t, r.frontend_dim), jnp.bfloat16)
        tokens = None
    if r.frontend == "vision_patches":
        kwargs["patch_embeds"] = jax.random.normal(KEY, (B, r.num_patches, r.d_model), jnp.bfloat16)
        kwargs["mrope_positions"] = jnp.broadcast_to(jnp.arange(t)[None, None], (3, B, t)).astype(jnp.int32)
    return tokens, kwargs


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_forward(name):
    r = _reduced(name)
    params = init_params(KEY, r)
    tokens, kwargs = _inputs(r, T)
    logits, cache, aux = forward(params, r, tokens, want_cache=r.has_decode, **kwargs)
    assert logits.shape == (B, T, r.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert float(aux) >= 0.0
    if r.has_decode:
        assert cache is not None


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_train_grad(name):
    """One loss+grad step on the reduced config: finite grads, no NaNs."""
    from repro.train.train_step import make_loss_fn

    r = _reduced(name)
    params = init_params(KEY, r)
    tokens, kwargs = _inputs(r, 16)
    batch = {"labels": jax.random.randint(KEY, (B, 16), 0, r.vocab_size)}
    if tokens is not None:
        batch["tokens"] = tokens
    batch.update(kwargs)
    loss_fn = make_loss_fn(r)
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    assert np.isfinite(float(loss))
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in gleaves)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in gleaves)


@pytest.mark.parametrize("name", [n for n in sorted(ARCHS) if ARCHS[n].has_decode])
def test_prefill_decode_consistency(name):
    r = _reduced(name)
    params = init_params(KEY, r)
    tokens, kwargs = _inputs(r, T + 1)
    kw_pre = dict(kwargs)
    kw_dec = {}
    if r.frontend == "vision_patches":
        kw_pre["mrope_positions"] = kwargs["mrope_positions"][:, :, :T]
        kw_dec["mrope_positions"] = jnp.full((3, B, 1), T, jnp.int32)
    full_logits, _, _ = forward(params, r, tokens, **kwargs)
    _, cache, _ = forward(
        params, r, None if tokens is None else tokens[:, :T], want_cache=True, cache_len=T + 8, **kw_pre
    )
    dl, new_cache = decode_step(
        params, r, cache, tokens[:, T : T + 1], jnp.full((B,), T, jnp.int32), **kw_dec
    )
    a = np.asarray(full_logits[:, T])
    b = np.asarray(dl[:, 0])
    err = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert err < 2e-2, f"{name}: prefill+decode diverges from full forward ({err:.3e})"
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_param_counts_match_analytic():
    """config.param_count() (used for MODEL_FLOPS) matches the real pytree."""
    for name in ("llama3.2-3b", "gemma2-9b", "qwen3-moe-235b-a22b", "rwkv6-1.6b"):
        cfg = get_config(name)
        shapes = jax.eval_shape(lambda c=cfg: init_params(jax.random.PRNGKey(0), c))
        real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        assert abs(real - cfg.param_count()) / real < 0.02, (name, real, cfg.param_count())
