"""Layer-2 audit primitives: CompileCounter, the no_recompiles guard, the
scan-carry dtype checker and the closure-constant walk — against tiny
throwaway programs whose compile behaviour is fully controlled here.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402

from repro.analysis.trace_audit import (  # noqa: E402
    CompileCounter,
    RecompileError,
    check_scan_carry_stability,
    closure_constants,
    no_recompiles,
    scan_carries,
)


def test_counter_sees_cold_trace_then_silent_warm():
    @jax.jit
    def f(x):
        return jnp.cumsum(x) * 2.0

    x = jnp.arange(7.0)
    with CompileCounter() as cold:
        f(x).block_until_ready()
    assert cold.traces >= 1
    # with REPRO_COMPILE_CACHE set the backend compile may be answered by
    # the persistent cache instead — either way the counter must see it
    assert cold.compiles >= 1 or cold.cache_hits >= 1

    x2 = x + 1.0  # eager add compiles here, OUTSIDE the warm counter
    with CompileCounter() as warm:
        f(x2).block_until_ready()  # same shape/dtype: jit-cache hit
    assert warm.snapshot() == {
        "traces": 0,
        "compiles": 0,
        "cache_hits": 0,
        "cache_misses": 0,
    }


def test_counter_detects_shape_driven_retrace():
    @jax.jit
    def f(x):
        return x.sum()

    f(jnp.ones(4))
    with CompileCounter() as cc:
        f(jnp.ones(5))  # new shape: must retrace
    assert cc.traces >= 1


def test_counter_stops_counting_after_exit():
    @jax.jit
    def f(x):
        return x * x

    with CompileCounter() as cc:
        pass
    f(jnp.ones(3))  # fresh compile AFTER the context closed
    assert cc.traces == 0 and cc.compiles == 0


def test_no_recompiles_passes_warm_and_raises_cold():
    @jax.jit
    def f(x):
        return x - 1.0

    warm_in = jnp.zeros(6)
    f(jnp.ones(6))
    with no_recompiles("warm repeat"):
        f(warm_in)

    with pytest.raises(RecompileError, match="retrace"):
        with no_recompiles("cold section"):
            f(jnp.ones(9))  # new shape inside the guard


def test_no_recompiles_allowance():
    @jax.jit
    def f(x):
        return x + 2.0

    cold_in = jnp.ones(11)
    # one fresh pjit call logs two jaxpr_trace events on jax 0.4.37 (the
    # abstract trace and the lowering pass) — the allowance is per event
    with no_recompiles("first compile allowed", allow_traces=2, allow_compiles=1):
        f(cold_in)


def test_no_recompiles_fixture(no_recompiles):
    @jax.jit
    def f(x):
        return x * 3.0

    warm_in = jnp.ones(13) * 2
    f(jnp.ones(13))
    with no_recompiles("fixture warm"):
        f(warm_in)


# ---------------------------------------------------------------------------
# jaxpr structure checks
# ---------------------------------------------------------------------------


def test_scan_carries_reports_nested_dtypes():
    def step(c, x):
        s, n = c
        return (s + x, n + 1), s

    @jax.jit
    def run(xs):
        return lax.scan(step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), xs)

    reps = scan_carries(run, jnp.ones(5, jnp.float32))
    scan_reps = [r for r in reps if r.primitive == "scan"]
    assert {r.dtype for r in scan_reps} == {"float32", "int32"}
    assert all(r.shape == () for r in scan_reps)


def test_carry_stability_flags_forbidden_dtype():
    def step(c, x):
        return c + x.astype(c.dtype), None

    def run32(xs):
        return lax.scan(step, jnp.zeros((), jnp.float32), xs)

    def run64(xs):
        return lax.scan(step, jnp.zeros((), jnp.float64), xs)

    xs = jnp.ones(4, jnp.float32)
    assert check_scan_carry_stability(run32, xs, forbid_dtypes=("float32",))
    from repro.sim.device_timeline import _x64_ctx

    with _x64_ctx():
        xs64 = jnp.ones(4, jnp.float64)
        assert not check_scan_carry_stability(run64, xs64, forbid_dtypes=("float32",))


def test_closure_constants_flags_only_giants():
    big = np.ones((1 << 15,), np.float64)  # 256 KiB
    small = np.ones((8,), np.float64)

    def with_big(x):
        return x + jnp.asarray(big)

    def with_small(x):
        return x * jnp.asarray(small)

    giants = closure_constants(with_big, jnp.ones(1 << 15), min_bytes=1 << 17)
    assert len(giants) == 1 and giants[0].nbytes == big.nbytes

    assert closure_constants(with_small, jnp.ones(8), min_bytes=1 << 17) == []


def test_closure_constants_recurses_into_scan():
    table = np.arange(1 << 14, dtype=np.float64)  # 128 KiB, captured in the body

    def step(c, x):
        return c + jnp.asarray(table)[0] * x, None

    def run(xs):
        return lax.scan(step, jnp.zeros(()), xs)

    giants = closure_constants(run, jnp.ones(3), min_bytes=1 << 16)
    assert any(g.nbytes == table.nbytes for g in giants)
