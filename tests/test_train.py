"""Training substrate: optimizer math, accumulation equivalence, learning."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.train import OptimizerConfig, TrainConfig, init_train_state, make_train_step
from repro.models import init_params


def _setup(accum=1, moment_dtype="float32"):
    cfg = get_config("llama3.2-3b").reduced()
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=50, moment_dtype=moment_dtype)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, opt)
    step = make_train_step(cfg, TrainConfig(accum_steps=accum, optimizer=opt))
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=1))
    return cfg, state, step, data


def test_accumulation_equivalence():
    """accum=1 and accum=4 produce (nearly) the same update on one batch."""
    _, s1, step1, data = _setup(accum=1)
    _, s4, step4, _ = _setup(accum=4)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    n1, m1 = step1(s1, batch)
    n4, m4 = step4(s4, batch)
    # loss means agree
    assert np.isclose(float(m1["loss"]), float(m4["loss"]), rtol=5e-3)
    # Adam amplifies f32 summation-order differences on rarely-touched rows
    # (tiny nu denominators), and one bf16 ULP is ~2e-3 at param magnitudes
    # ~0.25 — so equivalence means "within a couple of bf16 ULPs":
    for a, b in zip(jax.tree.leaves(n1["params"]), jax.tree.leaves(n4["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=5e-2, atol=2.5e-3
        )


def test_loss_decreases():
    cfg, state, step, data = _setup()
    jstep = jax.jit(step)
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = jstep(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses[:3] + losses[-3:]


def test_grad_clipping_and_lr_schedule():
    from repro.train.optimizer import schedule

    opt = OptimizerConfig(peak_lr=1e-2, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(schedule(opt, jnp.asarray(0))) == 0.0
    assert np.isclose(float(schedule(opt, jnp.asarray(10))), 1e-2, rtol=1e-2)
    assert float(schedule(opt, jnp.asarray(100))) >= 1e-3 - 1e-9


def test_moment_dtype_bf16():
    _, state, step, data = _setup(moment_dtype="bfloat16")
    assert all(a.dtype == jnp.bfloat16 for a in jax.tree.leaves(state["opt"]["mu"]))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    new_state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert all(a.dtype == jnp.bfloat16 for a in jax.tree.leaves(new_state["opt"]["mu"]))


def test_data_pipeline_deterministic_and_masked():
    data = SyntheticLMData(DataConfig(vocab_size=512, seq_len=64, global_batch=4, seed=3))
    a, b = data.batch(7), data.batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 64)
    # labels are next-token shifted
    row = np.random.default_rng(np.random.SeedSequence([3, 7, 0]))
    assert a["mask"].min() >= 0 and a["mask"].max() <= 1
    assert not np.array_equal(a["tokens"], data.batch(8)["tokens"])
