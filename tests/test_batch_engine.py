"""The batched multi-method engine matches the sequential Python oracle
(progressive error mode) for every method, every fraction — including the
n_train = 0 and n_train = n edge cases — plus packing and k-sweep checks."""

import numpy as np
import pytest

from repro.core.ksegments import KSegmentsConfig
from repro.sim import generate_eager
from repro.sim.batch_engine import GRID_METHODS, simulate_grid, simulate_ksweep
from repro.sim.jax_sim import ENGINE_METHODS
from repro.sim.simulator import SimConfig, simulate_suite, simulate_task
from repro.sim.traces import pack_traces

MIN_EXECS = 10


@pytest.fixture(scope="module")
def workflow():
    return generate_eager(seed=5, scale=0.12)


@pytest.fixture(scope="module")
def cfg():
    return SimConfig(min_executions=MIN_EXECS, ksegments=KSegmentsConfig(error_mode="progressive"))


@pytest.fixture(scope="module")
def grid(workflow, cfg):
    # 0.0 and 1.0 are the fraction-masking edge cases: every execution is
    # test (the first scored against the default allocation), resp. none is.
    res = simulate_grid([workflow], ENGINE_METHODS, (0.0, 0.5, 1.0), cfg)
    return {(r.workflow, r.task, r.method, r.train_frac): r for r in res}


def _assert_matches(got, ref):
    assert got.n_train == ref.n_train and got.n_test == ref.n_test
    # f32 (engine) vs f64 (oracle) can flip knife-edge failure decisions on
    # a few executions; totals and retries must agree closely and the bulk
    # of per-execution outcomes must match.
    np.testing.assert_allclose(got.wastage_gib_s.sum(), ref.wastage_gib_s.sum(), rtol=0.05, atol=1e-6)
    assert abs(int(got.retries.sum()) - int(ref.retries.sum())) <= max(2, 0.1 * ref.retries.sum())
    if ref.n_test:
        close = np.isclose(got.wastage_gib_s, ref.wastage_gib_s, rtol=0.05, atol=0.5)
        assert close.mean() > 0.9


@pytest.mark.parametrize("method", ENGINE_METHODS)
@pytest.mark.parametrize("frac", [0.0, 0.5])
def test_engine_parity_per_method(workflow, cfg, grid, method, frac):
    for trace in workflow.eligible_tasks(MIN_EXECS)[:2]:
        ref = simulate_task(trace, method, frac, cfg)
        _assert_matches(grid[(trace.workflow, trace.name, method, frac)], ref)


def test_full_training_fraction_has_no_tests(workflow, grid):
    for trace in workflow.eligible_tasks(MIN_EXECS):
        r = grid[(trace.workflow, trace.name, "ksegments-selective", 1.0)]
        assert r.n_test == 0 and len(r.wastage_gib_s) == 0
        assert r.mean_wastage == 0.0 and r.mean_retries == 0.0


def test_grid_rows_align_with_sequential_suite(workflow, cfg):
    """Same row ordering and metadata as simulate_suite, cell for cell."""
    batched = simulate_grid([workflow], GRID_METHODS, (0.5,), cfg)
    sequential = simulate_suite([workflow], GRID_METHODS, (0.5,), cfg)
    assert len(batched) == len(sequential)
    for b, s in zip(batched, sequential):
        assert (b.workflow, b.task, b.method, b.train_frac) == (s.workflow, s.task, s.method, s.train_frac)
        assert (b.n_train, b.n_test) == (s.n_train, s.n_test)


def test_ksweep_matches_sequential_per_k(workflow, cfg):
    trace = max(workflow.tasks, key=lambda t: t.n_executions)
    sweep = simulate_ksweep(trace, (1, 3, 6), 0.5, cfg)
    for k in (1, 3, 6):
        ref = simulate_task(trace, "ksegments-selective", 0.5, SimConfig(ksegments=KSegmentsConfig(k=k, error_mode="progressive")))
        _assert_matches(sweep[k], ref)


def test_pack_traces_shapes(workflow):
    tasks = workflow.eligible_tasks(MIN_EXECS)
    batches = pack_traces(tasks)
    assert sum(len(b.tasks) for b in batches) == len(tasks)
    for b in batches:
        L, B, T = b.shape
        assert b.x.shape == (L, B) and b.lengths.shape == (L, B) and len(b.tasks) == L
        for li, t in enumerate(b.tasks):
            n = t.n_executions
            assert b.n_execs[li] == n and n <= B and t.max_samples() <= T
            assert b.default_mib[li] == t.default_mib
            # real data in the prefix, inert zeros in the tail
            assert np.all(b.lengths[li, :n] > 0) and np.all(b.lengths[li, n:] == 0)
            assert np.all(b.y[li, n:] == 0.0)
            np.testing.assert_array_equal(b.x[li, :n], [e.input_size for e in t.executions])


def test_to_padded_batch_filters_eligibility(workflow):
    batches = workflow.to_padded_batch(MIN_EXECS)
    packed = {t.name for b in batches for t in b.tasks}
    assert packed == {t.name for t in workflow.eligible_tasks(MIN_EXECS)}
